"""Pallas megakernel: batched, bit-packed bytes→verdict streaming filter.

The closest TPU realization of the paper's architecture (Fig 5), and the
default device hot path of ``StreamingEngine``: the whole event→verdict
datapath runs as ONE fused kernel so that — exactly like the FPGA, where
parser and filter share a chip and every symbol advances all query blocks
in a single clock (§1, §3.2–3.4) — no per-event tensor ever leaves the
core.

Layout (see the README "Kernel hot path" diagram):

* **grid = (documents × state-word blocks)** — each program owns one
  document and one block of ≤BLK states *closed under parent pointers*
  (:func:`repro.kernels.blocks.state_layout` mirrors the paper's §3.3
  sort-and-cluster flow), so blocks never communicate — the property
  that lets the paper tile thousands of profiles.  Sharded plans fold
  their part axis into this block axis: more profiles are just more
  blocks, the paper's profiles-across-chips replication.
* **state = packed uint32 words in VMEM, end to end** — the document
  stack is a ``(max_depth+2, BLK/32)`` packed-word buffer in VMEM, the
  on-chip analogue of the FPGA's block-RAM tag stack (§3.2).  There is
  no per-event unpack/repack: the per-event transition is a per-tag
  word-mask row gather plus an in-block parent word/bit gather and three
  bitwise ops — replacing both the scan path's unpack→gather→pack round
  trip and the old float32 ``(BLK, BLK)`` parent matmul.
* **events stream through SMEM chunks** — the fused ``(kind<<16)|tag``
  event words are DMA'd from HBM into a double-buffered SMEM scratch
  (the "8-bit streaming XML interface" of Fig 3); the prefetch of chunk
  *k+1* overlaps the event loop on chunk *k*.

Outputs per (document, block): the block's accept-lane verdict bits and
first-match event indices; the caller maps lanes back to queries (the
paper's priority encoder).

* **fused sparse epilogue** (``stream_filter_pallas_sparse`` /
  ``stream_filter_bytes_pallas_sparse``) — the sparse-delivery launch
  shape: instead of the dense ``(B, G, QB)`` accept bitmap, each program
  compacts its own accept lanes in VMEM at end-of-document and appends
  ``(doc_id, accept_class, first_event)`` rows to ONE bounded
  ``(match_cap + win, 3)`` output buffer.  Cross-program coordination is
  a running SMEM counter in a constant-index-map output block: TPU grids
  execute *sequentially*, so reading the counter is a race-free
  exclusive scan over the grid — no atomics, and the only HBM traffic on
  the verdict side is O(match_cap), the paper's match-tuples-not-bitmaps
  delivery argument pushed all the way into the kernel.

Host oracles: :func:`repro.kernels.ref.stream_filter_words` (pure-jnp
scan of one word-block over the same packed tables — the unit-level
ground truth, tests/test_kernels.py asserts exact agreement) and the
``StreamingEngine`` ``lax.scan`` path (``kernel="scan"``, the end-to-end
oracle — tests/test_megakernel.py asserts the kernel is *bit-identical*
to it on ragged batches, churned plans and depth-overflow documents).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import parse as parse_mod
from . import ref
from .blocks import _round_up

NO_MATCH = jnp.iinfo(jnp.int32).max

#: fused event word: kind in the high half, tag (uint16 view) in the low
KIND_SHIFT = 16
TAG_MASK = 0xFFFF


def fuse_events(kind: jax.Array, tag: jax.Array) -> jax.Array:
    """(B, N) kind/tag → one int32 event word per event.

    One word per event means one SMEM scalar read per event inside the
    kernel (and one DMA stream instead of two).  PAD events keep working
    unchanged: their kind gates every state/stack/accept update off.
    """
    return ((kind.astype(jnp.int32) << KIND_SHIFT)
            | (tag.astype(jnp.int32) & TAG_MASK))


def _block_tables(tagmask_ref, pw_ref, pb_ref, self_ref, accw_ref,
                  accb_ref):
    """Load this program's block tables once, before the event loop."""
    wb = self_ref.shape[1]
    return dict(
        pw=pw_ref[0],                      # (WB, 32) parent word per lane
        pb=pb_ref[0].astype(jnp.uint32),   # (WB, 32) parent bit per lane
        selfw=self_ref[0, :],              # (WB,) packed self-loop states
        accw=accw_ref[0, :],               # (QB,) accept-lane word
        accb=accb_ref[0, :].astype(jnp.uint32),
        tagmask_ref=tagmask_ref,
        lane=jax.lax.broadcasted_iota(jnp.uint32, (wb, 32), 1))


def _advance(ev, i, depth, matched, first, stack_ref, tb, *,
             max_depth: int, n_tags: int):
    """One fused event word through one state-word block.

    THE per-event transition, shared verbatim by the event-stream kernel
    (:func:`stream_filter_pallas`) and the one-launch bytes kernel
    (:func:`stream_filter_bytes_pallas`) — one definition, so the two
    launch shapes can never drift apart semantically.  ``i`` is the
    document-local event ordinal reported as the first-match index.
    """
    k = ev >> KIND_SHIFT
    t = ev & TAG_MASK
    is_open = k == ref.OPEN
    is_close = k == ref.CLOSE
    row = stack_ref[pl.ds(depth, 1), :][0]              # (WB,) packed TOS
    tclip = jnp.where((t >= 0) & (t < n_tags), t, n_tags)
    trow = tb["tagmask_ref"][0, pl.ds(tclip, 1), :][0]  # per-tag words
    # in-block parent gather, packed → packed (no unpack/repack of the
    # stack rows; only the 32 source lanes expand)
    bits = (jnp.take(row, tb["pw"], axis=0) >> tb["pb"]) & jnp.uint32(1)
    src = jnp.sum(bits << tb["lane"], axis=1, dtype=jnp.uint32)
    nxt = (src & trow) | (tb["selfw"] & row)
    # push on open (write at depth+1), no-op otherwise — exactly the
    # scan path's clip discipline, so depth overflow degrades
    # identically on both paths
    widx = jnp.clip(depth + 1, 0, max_depth + 1)
    old = stack_ref[pl.ds(widx, 1), :]
    stack_ref[pl.ds(widx, 1), :] = jnp.where(is_open, nxt[None], old)
    depth = jnp.clip(
        depth + jnp.where(is_open, 1, jnp.where(is_close, -1, 0)),
        0, max_depth + 1)
    accbits = (jnp.take(nxt, tb["accw"], axis=0)
               >> tb["accb"]) & jnp.uint32(1)
    active = is_open & (accbits != 0)
    newly = active & ~matched
    first = jnp.where(newly, i, first)
    matched = matched | active
    return depth, matched, first


def _stream_events(ev_ref, evbuf_ref, sem_ref, stack_ref, tb, doc, *,
                   n_events: int, max_depth: int, chunk: int, n_tags: int,
                   qb: int):
    """Double-buffered event loop of ONE (document, block) program.

    Shared by the dense kernel (:func:`_kernel`) and the fused-sparse
    kernel (:func:`_kernel_sparse`) so the two launch shapes can never
    drift: DMA this document's fused event words HBM→SMEM chunk by
    chunk (prefetching chunk *k+1* under chunk *k*'s event loop) and run
    :func:`_advance` per event.  Returns (matched, first) for the
    block's ``qb`` accept lanes.
    """
    n_chunks = n_events // chunk

    def event_dma(slot, ci):
        # one chunk of this document's fused event words: HBM → SMEM
        return pltpu.make_async_copy(
            ev_ref.at[doc, pl.ds(ci * chunk, chunk)],
            evbuf_ref.at[slot], sem_ref.at[slot])

    event_dma(0, 0).start()

    def chunk_body(ci, carry):
        slot = jax.lax.rem(ci, 2)

        # prefetch chunk ci+1 into the other buffer while ci computes
        @pl.when(ci + 1 < n_chunks)
        def _():
            event_dma(1 - slot, ci + 1).start()

        event_dma(slot, ci).wait()

        def ev_body(j, carry):
            depth, matched, first = carry
            return _advance(evbuf_ref[slot, j], ci * chunk + j, depth,
                            matched, first, stack_ref, tb,
                            max_depth=max_depth, n_tags=n_tags)

        return jax.lax.fori_loop(0, chunk, ev_body, carry)

    depth, matched, first = jax.lax.fori_loop(
        0, n_chunks, chunk_body,
        (jnp.int32(0), jnp.zeros((qb,), bool),
         jnp.full((qb,), NO_MATCH, jnp.int32)))
    return matched, first


def _kernel(ev_ref, tagmask_ref, pw_ref, pb_ref, self_ref, init_ref,
            accw_ref, accb_ref, matched_ref, first_ref,
            stack_ref, evbuf_ref, sem_ref, *, n_events: int,
            max_depth: int, chunk: int, n_tags: int, doc_axis: int):
    b = pl.program_id(doc_axis)
    qb = accw_ref.shape[1]
    # fresh document: zero the VMEM stack, root context at depth 0
    stack_ref[...] = jnp.zeros_like(stack_ref)
    stack_ref[0, :] = init_ref[0, :]
    tb = _block_tables(tagmask_ref, pw_ref, pb_ref, self_ref, accw_ref,
                       accb_ref)
    matched, first = _stream_events(
        ev_ref, evbuf_ref, sem_ref, stack_ref, tb, b, n_events=n_events,
        max_depth=max_depth, chunk=chunk, n_tags=n_tags, qb=qb)
    matched_ref[0, 0, :] = matched.astype(jnp.int32)
    first_ref[0, 0, :] = first


# ------------------------------------------------- fused sparse epilogue
def _sparse_init(buf_ref, cnt_ref):
    """First grid step: empty the shared match buffer and the counter.

    Both live in constant-index-map output blocks, so they stay resident
    on core across every grid step (TPU grids run *sequentially*) and
    flush to HBM exactly once, after the last step — the property that
    makes a running SMEM counter a race-free exclusive scan over the
    whole grid, with no atomics.
    """

    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _():
        col = jax.lax.broadcasted_iota(jnp.int32, buf_ref.shape, 1)
        buf_ref[...] = jnp.where(col == 2, NO_MATCH, -1)
        cnt_ref[0, 0] = 0


def _emit_rows(matched, first, cls_row, doc, buf_ref, cnt_ref, *,
               cap: int, win: int):
    """End-of-document epilogue of ONE program: compact this block's
    accept lanes straight into the shared bounded match buffer.

    ``matched``/``first``/``cls_row`` are the block's ``(QB,)`` lane
    outputs and accept-class names (``-1`` = inert lane); ``doc`` the
    global document id (``< 0`` = unused slot, dropped).  Hits rank by
    an in-register cumsum and land via masked sums (Mosaic has no
    scatter) as ``(doc, class, first)`` rows in a ``win``-row window at
    the current counter — reading the counter IS this program's slice of
    the cross-grid exclusive scan (see :func:`_sparse_init`).  Writes
    saturate at ``cap`` (the buffer has ``win`` spare tail rows, so a
    clamped window never corrupts valid rows) while the counter keeps
    the TRUE total — ``count > cap`` is the caller's overflow signal.
    """
    qb = matched.shape[0]
    hits = matched & (cls_row >= 0)
    nv = jnp.sum(hits.astype(jnp.int32))

    @pl.when((nv > 0) & (doc >= 0))
    def _():
        cnt = cnt_ref[0, 0]
        incl = (jax.lax.broadcasted_iota(jnp.int32, (qb, qb), 1)
                <= jax.lax.broadcasted_iota(jnp.int32, (qb, qb), 0))
        rank = jnp.sum((incl & hits[None, :]).astype(jnp.int32),
                       axis=1) - 1                                # (qb,)
        out = jax.lax.broadcasted_iota(jnp.int32, (win, qb), 0)
        mask = ((out == rank[None, :]) & hits[None, :]).astype(jnp.int32)
        cls_c = jnp.sum(mask * cls_row[None, :], axis=1)          # (win,)
        fst_c = jnp.sum(mask * first[None, :], axis=1)
        col = jax.lax.broadcasted_iota(jnp.int32, (win, 3), 1)
        rows = jnp.where(col == 0, doc,
                         jnp.where(col == 1, cls_c[:, None],
                                   fst_c[:, None]))
        valid = jax.lax.broadcasted_iota(jnp.int32, (win, 3), 0) < nv
        start = jnp.minimum(cnt, cap)     # saturating write offset
        old = buf_ref[pl.ds(start, win), :]
        buf_ref[pl.ds(start, win), :] = jnp.where(valid, rows, old)
        cnt_ref[0, 0] = cnt + nv          # true count, never clamped


def _kernel_sparse(ev_ref, docid_ref, tagmask_ref, pw_ref, pb_ref,
                   self_ref, init_ref, accw_ref, accb_ref, lane_ref,
                   buf_ref, cnt_ref, stack_ref, evbuf_ref, sem_ref, *,
                   n_events: int, max_depth: int, chunk: int, n_tags: int,
                   doc_axis: int, cap: int, win: int):
    """Sparse twin of :func:`_kernel`: same streamed transition, but the
    per-(document, block) accept lanes compact in VMEM at end-of-document
    and only the bounded match buffer ever reaches HBM."""
    b = pl.program_id(doc_axis)
    qb = accw_ref.shape[1]
    _sparse_init(buf_ref, cnt_ref)
    stack_ref[...] = jnp.zeros_like(stack_ref)
    stack_ref[0, :] = init_ref[0, :]
    tb = _block_tables(tagmask_ref, pw_ref, pb_ref, self_ref, accw_ref,
                       accb_ref)
    matched, first = _stream_events(
        ev_ref, evbuf_ref, sem_ref, stack_ref, tb, b, n_events=n_events,
        max_depth=max_depth, chunk=chunk, n_tags=n_tags, qb=qb)
    _emit_rows(matched, first, lane_ref[0, :], docid_ref[0, 0],
               buf_ref, cnt_ref, cap=cap, win=win)


#: megakernel grid iteration orders — ``"bg"`` walks documents in the
#: outer loop (block tables re-streamed per document), ``"gb"`` walks
#: blocks outermost (each block's tables stay resident across the whole
#: batch).  Which wins depends on (batch, n_blocks, table bytes) — an
#: autotune dimension (:mod:`repro.kernels.autotune`), not a constant.
GRID_ORDERS = ("bg", "gb")


def _grid_maps(grid_order: str, bsz: int, g: int):
    """(grid, doc_axis, by-block index map, by-doc-and-block index map)."""
    if grid_order not in GRID_ORDERS:
        raise ValueError(
            f"grid_order={grid_order!r} is not one of {GRID_ORDERS}")
    if grid_order == "gb":
        return ((g, bsz), 1,
                lambda gg, b: (gg,),
                lambda gg, b: (b, gg))
    return ((bsz, g), 0,
            lambda b, gg: (gg,),
            lambda b, gg: (b, gg))


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "chunk", "interpret",
                                    "grid_order"))
def stream_filter_pallas(events: jax.Array, tagmask: jax.Array,
                         pw: jax.Array, pb: jax.Array,
                         selfloop_words: jax.Array, init_words: jax.Array,
                         acc_word: jax.Array, acc_bit: jax.Array, *,
                         max_depth: int, chunk: int = 256,
                         interpret: bool | None = None,
                         grid_order: str = "bg"
                         ) -> tuple[jax.Array, jax.Array]:
    """Run every (document × state-word block) over the event stream.

    events (B, N) int32 fused words (:func:`fuse_events`); block tables
    as emitted by :func:`repro.kernels.blocks.state_layout`: tagmask
    (G, T+1, WB) uint32, pw/pb (G, WB, 32) int32, selfloop/init words
    (G, WB) uint32, acc_word/acc_bit (G, QB) int32.  ``max_depth`` is
    the *plan's* stack bound — callers thread it from plan metadata so
    kernel and scan can never disagree.  Returns matched (B, G, QB)
    int32 0/1 and first (B, G, QB) int32 accept-lane outputs.
    ``interpret=None`` auto-detects from the backend; ``grid_order``
    picks the grid iteration order (:data:`GRID_ORDERS`).
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    bsz, n = events.shape
    g, wb = selfloop_words.shape
    qb = acc_word.shape[1]
    n_tags = tagmask.shape[1] - 1
    # pad the event axis to whole SMEM chunks with inert PAD events (a
    # short stream shrinks the chunk instead of inflating the pad tail)
    chunk = max(32, min(int(chunk), _round_up(n, 32)))
    npad = _round_up(n, chunk)
    if npad != n:
        events = jnp.pad(events, ((0, 0), (0, npad - n)),
                         constant_values=ref.PAD << KIND_SHIFT)
    grid, doc_axis, by_block, by_doc_block = _grid_maps(grid_order, bsz, g)
    matched, first = pl.pallas_call(
        functools.partial(_kernel, n_events=npad, max_depth=max_depth,
                          chunk=chunk, n_tags=n_tags, doc_axis=doc_axis),
        grid=grid,
        in_specs=[
            # events stay off-core; the kernel DMAs SMEM chunks itself
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, n_tags + 1, wb),
                         lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qb), lambda *ids: by_doc_block(*ids) + (0,)),
            pl.BlockSpec((1, 1, qb), lambda *ids: by_doc_block(*ids) + (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, g, qb), jnp.int32),
            jax.ShapeDtypeStruct((bsz, g, qb), jnp.int32),
        ],
        scratch_shapes=[
            # the paper's block-RAM tag stack: packed words in VMEM
            pltpu.VMEM((max_depth + 2, wb), jnp.uint32),
            # double-buffered event chunks (the streaming interface)
            pltpu.SMEM((2, chunk), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(events, tagmask, pw, pb, selfloop_words, init_words,
      acc_word, acc_bit)
    return matched, first


def _epilogue_window(qb: int, ep_tile: int) -> int:
    """Emission-window rows per program: ``qb`` lanes can all hit, and
    the read-modify-write window is sublane-tiled by ``ep_tile`` (the
    autotunable epilogue knob — bigger tiles align the dynamic-offset
    window write, smaller ones shrink the per-flush masked sums)."""
    return _round_up(qb, max(8, int(ep_tile)))


@functools.partial(jax.jit,
                   static_argnames=("cap", "max_depth", "chunk",
                                    "interpret", "grid_order", "ep_tile"))
def stream_filter_pallas_sparse(events: jax.Array, doc_ids: jax.Array,
                                tagmask: jax.Array, pw: jax.Array,
                                pb: jax.Array, selfloop_words: jax.Array,
                                init_words: jax.Array, acc_word: jax.Array,
                                acc_bit: jax.Array, lane_cls: jax.Array, *,
                                cap: int, max_depth: int, chunk: int = 256,
                                interpret: bool | None = None,
                                grid_order: str = "bg", ep_tile: int = 8
                                ) -> tuple[jax.Array, jax.Array]:
    """One launch events → bounded match list: the fused sparse epilogue.

    Same grid and tables as :func:`stream_filter_pallas`, but the
    ``(B, G, QB)`` accept bitmap never leaves VMEM: each program
    compacts its own accept lanes at end-of-document into a single
    shared ``(cap + win, 3)`` int32 buffer of ``(doc_id, accept_class,
    first_event)`` rows, coordinated by a running SMEM counter that the
    sequential TPU grid turns into an exclusive scan (no atomics).
    ``doc_ids`` (B, 1) int32 names each batch row globally (``< 0``
    drops the row — segment pads); ``lane_cls`` (G, QB) int32 names
    each lane's accept class (``-1`` = inert).  Returns ``(buf, count)``
    where only ``buf[:min(count, cap)]`` rows are valid and
    ``count > cap`` signals overflow (rows past ``cap`` are clamped
    into the ``win``-row spare tail); row order is grid emission order,
    not sorted.  ``ep_tile`` tiles the emission window
    (:func:`_epilogue_window`).
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    bsz, n = events.shape
    g, wb = selfloop_words.shape
    qb = acc_word.shape[1]
    n_tags = tagmask.shape[1] - 1
    win = _epilogue_window(qb, ep_tile)
    capp = int(cap) + win
    chunk = max(32, min(int(chunk), _round_up(n, 32)))
    npad = _round_up(n, chunk)
    if npad != n:
        events = jnp.pad(events, ((0, 0), (0, npad - n)),
                         constant_values=ref.PAD << KIND_SHIFT)
    grid, doc_axis, by_block, by_doc_block = _grid_maps(grid_order, bsz, g)
    buf, cnt = pl.pallas_call(
        functools.partial(_kernel_sparse, n_events=npad,
                          max_depth=max_depth, chunk=chunk, n_tags=n_tags,
                          doc_axis=doc_axis, cap=int(cap), win=win),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, 1), lambda *ids: (by_doc_block(*ids)[0], 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_tags + 1, wb),
                         lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
        ],
        out_specs=[
            # constant index maps: the match buffer and counter persist
            # on core across the WHOLE grid and flush to HBM once
            pl.BlockSpec((capp, 3), lambda *ids: (0, 0)),
            pl.BlockSpec((1, 1), lambda *ids: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capp, 3), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((max_depth + 2, wb), jnp.uint32),
            pltpu.SMEM((2, chunk), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(events, doc_ids, tagmask, pw, pb, selfloop_words, init_words,
      acc_word, acc_bit, lane_cls)
    return buf, cnt


def _event_capacity(chunk: int) -> int:
    """Worst-case events per ``chunk`` bytes, rounded for VMEM layout.

    Predecode validates tag symbols but not ``>`` (§3.1's fixed-length
    dictionary makes the closer redundant), so on adversarial input an
    event can start every 3 bytes (``<a`` + one byte).  ``+4`` covers
    the lookahead overhang events whose ``<`` sits in the last 3 bytes.
    """
    return _round_up(chunk // 3 + 4, 8)


def _bytes_stream(data_ref, starts_ref, stack_ref, mbuf_ref, fbuf_ref,
                  bbuf_ref, evbuf_ref, sem_ref, tb, init_row, seg, *,
                  n_bytes: int, max_depth: int, chunk: int, n_tags: int,
                  qb: int):
    """Streaming body of the one-launch bytes kernel, one grid cell.

    Shared verbatim by the dense (:func:`_bytes_kernel`) and
    fused-sparse (:func:`_bytes_kernel_sparse`) launch shapes.  Per
    chunk of raw bytes: DMA the int32-packed bytes HBM→VMEM
    (double-buffered, one lookahead word), classify every position with
    :func:`repro.kernels.parse.fused_predecode`, compact the hits into a
    dense (word, byte-pos) event buffer via a ones-matmul cumsum and a
    masked-sum scatter (Mosaic has no in-kernel scatter), then run the
    shared :func:`_advance` transition per event.  The ``starts`` table
    (one int32 row per segment, INT32_MAX sentinel past the last doc)
    drives per-document resets: crossing a boundary flushes the finished
    document's accept lanes to the (D, QB) result buffers and re-roots
    the stack — this is how short documents share a grid slot instead of
    padding to the longest.  On return every document row of
    ``mbuf_ref``/``fbuf_ref`` is final.
    """
    n_words = chunk // 4
    n_chunks = n_bytes // chunk
    evcap = _event_capacity(chunk)
    s = seg

    # result buffers for every document in this segment; empty doc slots
    # keep these initial values (flushed by the boundary loop unchanged)
    mbuf_ref[...] = jnp.zeros_like(mbuf_ref)
    fbuf_ref[...] = jnp.full_like(fbuf_ref, NO_MATCH)
    stack_ref[...] = jnp.zeros_like(stack_ref)
    stack_ref[0, :] = init_row

    def byte_dma(slot, ci):
        # chunk bytes + one int32 lookahead word: HBM → VMEM
        return pltpu.make_async_copy(
            data_ref.at[s, pl.ds(ci * n_words, n_words + 1), :],
            bbuf_ref.at[slot], sem_ref.at[slot])

    byte_dma(0, 0).start()

    # static helpers for in-chunk compaction
    upper = (jax.lax.broadcasted_iota(jnp.float32, (chunk, chunk), 0)
             <= jax.lax.broadcasted_iota(jnp.float32, (chunk, chunk), 1)
             ).astype(jnp.float32)                      # inclusive cumsum
    eiota = jax.lax.broadcasted_iota(jnp.int32, (evcap, chunk), 0)
    shift = jax.lax.broadcasted_iota(
        jnp.uint32, (1, n_words + 1, 4), 2) * jnp.uint32(8)

    def chunk_body(ci, carry):
        slot = jax.lax.rem(ci, 2)

        @pl.when(ci + 1 < n_chunks)
        def _():
            byte_dma(1 - slot, ci + 1).start()

        byte_dma(slot, ci).wait()

        # unpack little-endian int32 words → one (1, chunk+4) byte row
        words = bbuf_ref[slot].reshape(1, n_words + 1, 1)
        bytes_row = ((words.astype(jnp.uint32) >> shift)
                     & jnp.uint32(0xFF)).astype(jnp.int32)
        bytes_row = bytes_row.reshape(1, 4 * (n_words + 1))
        b0 = bytes_row[:, 0:chunk]
        b1 = bytes_row[:, 1:chunk + 1]
        b2 = bytes_row[:, 2:chunk + 2]
        b3 = bytes_row[:, 3:chunk + 3]
        fused, keep = parse_mod.fused_predecode(b0, b1, b2, b3)
        keepf = keep.astype(jnp.float32)                 # (1, chunk)
        dest = (jnp.dot(keepf, upper,
                        preferred_element_type=jnp.float32)
                .astype(jnp.int32) - 1)                  # (1, chunk)
        cnt = dest[0, chunk - 1] + 1
        pos_row = ci * chunk + jax.lax.broadcasted_iota(
            jnp.int32, (1, chunk), 1)
        # masked-sum compaction: event j = Σ over positions with dest==j
        maskT = ((eiota == dest) & keep).astype(jnp.int32)  # (evcap, chunk)
        evbuf_ref[:, 0:1] = jnp.sum(maskT * fused, axis=1, keepdims=True)
        evbuf_ref[:, 1:2] = jnp.sum(maskT * pos_row, axis=1, keepdims=True)

        def ev_body(j, carry):
            d, nxt, depth, base, ord_, matched, first = carry
            erow = evbuf_ref[pl.ds(j, 1), :]
            ev = erow[0, 0]
            pos = erow[0, 1]

            # crossed one or more doc boundaries? flush and re-root.
            # ``nxt`` (the next boundary offset) rides in the carry so
            # the while cond stays ref-free; sentinel rows past the
            # last real document make it +inf-like, never crossed.
            def flush_cond(c):
                return pos >= c[1]

            def flush_body(c):
                dd, _, _, _, oo, mm, ff = c
                mbuf_ref[pl.ds(dd, 1), :] = mm.astype(jnp.int32)[None]
                fbuf_ref[pl.ds(dd, 1), :] = ff[None]
                stack_ref[0, :] = init_row
                return (dd + 1, starts_ref[0, dd + 2], jnp.int32(0),
                        oo, oo, jnp.zeros((qb,), bool),
                        jnp.full((qb,), NO_MATCH, jnp.int32))

            d, nxt, depth, base, ord_, matched, first = jax.lax.while_loop(
                flush_cond, flush_body,
                (d, nxt, depth, base, ord_, matched, first))
            depth, matched, first = _advance(
                ev, ord_ - base, depth, matched, first, stack_ref, tb,
                max_depth=max_depth, n_tags=n_tags)
            return d, nxt, depth, base, ord_ + 1, matched, first

        return jax.lax.fori_loop(0, cnt, ev_body, carry)

    d, nxt, depth, base, ord_, matched, first = jax.lax.fori_loop(
        0, n_chunks, chunk_body,
        (jnp.int32(0), starts_ref[0, 1], jnp.int32(0), jnp.int32(0),
         jnp.int32(0), jnp.zeros((qb,), bool),
         jnp.full((qb,), NO_MATCH, jnp.int32)))
    # flush the document the stream ended inside; remaining (empty) doc
    # slots keep their initial rows
    mbuf_ref[pl.ds(d, 1), :] = matched.astype(jnp.int32)[None]
    fbuf_ref[pl.ds(d, 1), :] = first[None]


def _bytes_kernel(data_ref, starts_ref, tagmask_ref, pw_ref, pb_ref,
                  self_ref, init_ref, accw_ref, accb_ref,
                  matched_ref, first_ref,
                  stack_ref, mbuf_ref, fbuf_ref, bbuf_ref, evbuf_ref,
                  sem_ref, *, n_bytes: int, max_depth: int, chunk: int,
                  n_tags: int, doc_axis: int):
    """One-launch bytes→verdict (dense): stream, then copy the per-doc
    accept-lane rows out (see :func:`_bytes_stream`)."""
    s = pl.program_id(doc_axis)
    qb = accw_ref.shape[1]
    tb = _block_tables(tagmask_ref, pw_ref, pb_ref, self_ref, accw_ref,
                       accb_ref)
    _bytes_stream(data_ref, starts_ref, stack_ref, mbuf_ref, fbuf_ref,
                  bbuf_ref, evbuf_ref, sem_ref, tb, init_ref[0, :], s,
                  n_bytes=n_bytes, max_depth=max_depth, chunk=chunk,
                  n_tags=n_tags, qb=qb)
    matched_ref[0, 0, :, :] = mbuf_ref[...]
    first_ref[0, 0, :, :] = fbuf_ref[...]


def _bytes_kernel_sparse(data_ref, starts_ref, docmap_ref, tagmask_ref,
                         pw_ref, pb_ref, self_ref, init_ref, accw_ref,
                         accb_ref, lane_ref, buf_ref, cnt_ref,
                         stack_ref, mbuf_ref, fbuf_ref, bbuf_ref,
                         evbuf_ref, sem_ref, *, n_bytes: int,
                         max_depth: int, chunk: int, n_tags: int,
                         n_docs: int, doc_axis: int, cap: int, win: int):
    """Sparse twin of :func:`_bytes_kernel`: after the stream, every
    document row of the segment compacts straight into the shared
    bounded match buffer (``docmap`` names each slot's global batch
    row; ``-1`` pad slots emit nothing)."""
    s = pl.program_id(doc_axis)
    qb = accw_ref.shape[1]
    _sparse_init(buf_ref, cnt_ref)
    tb = _block_tables(tagmask_ref, pw_ref, pb_ref, self_ref, accw_ref,
                       accb_ref)
    _bytes_stream(data_ref, starts_ref, stack_ref, mbuf_ref, fbuf_ref,
                  bbuf_ref, evbuf_ref, sem_ref, tb, init_ref[0, :], s,
                  n_bytes=n_bytes, max_depth=max_depth, chunk=chunk,
                  n_tags=n_tags, qb=qb)
    cls_row = lane_ref[0, :]

    def doc_body(dd, carry):
        matched = mbuf_ref[pl.ds(dd, 1), :][0] != 0
        first = fbuf_ref[pl.ds(dd, 1), :][0]
        _emit_rows(matched, first, cls_row, docmap_ref[0, dd],
                   buf_ref, cnt_ref, cap=cap, win=win)
        return carry

    jax.lax.fori_loop(0, n_docs, doc_body, jnp.int32(0))


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "chunk", "interpret",
                                    "grid_order"))
def stream_filter_bytes_pallas(data: jax.Array, starts: jax.Array,
                               tagmask: jax.Array, pw: jax.Array,
                               pb: jax.Array, selfloop_words: jax.Array,
                               init_words: jax.Array, acc_word: jax.Array,
                               acc_bit: jax.Array, *, max_depth: int,
                               chunk: int = 256,
                               interpret: bool | None = None,
                               grid_order: str = "bg"
                               ) -> tuple[jax.Array, jax.Array]:
    """One-launch raw bytes → per-document verdicts.

    data (S, L) uint8 packed segments; starts (S, D+1) int32 document
    start offsets per segment, INT32_MAX-filled past the last real
    document (see ``repro.core.events.SegmentPack``) — an unpacked batch
    is the degenerate D=1 with ``starts = [[0, INT32_MAX]] * B``.  Block
    tables as for :func:`stream_filter_pallas`.  ``chunk`` is *bytes*
    per DMA chunk here (the event kernel's chunk counts events).
    Returns matched/first (S, G, D, QB) int32 accept-lane outputs; the
    caller scatters document rows back to batch order.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    nseg, length = data.shape
    n_docs = starts.shape[1] - 1
    g, wb = selfloop_words.shape
    qb = acc_word.shape[1]
    n_tags = tagmask.shape[1] - 1
    chunk = max(32, min(_round_up(int(chunk), 32), _round_up(length, 32)))
    npad = _round_up(length, chunk)
    # + one int32 lookahead word so chunk-straddling tags decode whole
    data = jnp.pad(data, ((0, 0), (0, npad - length + 4)))
    words = jax.lax.bitcast_convert_type(
        data.reshape(nseg, npad // 4 + 1, 4), jnp.int32)[..., None]
    grid, doc_axis, by_block, by_doc_block = _grid_maps(grid_order, nseg, g)
    matched, first = pl.pallas_call(
        functools.partial(_bytes_kernel, n_bytes=npad, max_depth=max_depth,
                          chunk=chunk, n_tags=n_tags, doc_axis=doc_axis),
        grid=grid,
        in_specs=[
            # raw bytes stay off-core; the kernel DMAs VMEM chunks itself
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, n_docs + 1),
                         lambda *ids: by_doc_block(*ids)[:1] + (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_tags + 1, wb),
                         lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n_docs, qb),
                         lambda *ids: by_doc_block(*ids) + (0, 0)),
            pl.BlockSpec((1, 1, n_docs, qb),
                         lambda *ids: by_doc_block(*ids) + (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nseg, g, n_docs, qb), jnp.int32),
            jax.ShapeDtypeStruct((nseg, g, n_docs, qb), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((max_depth + 2, wb), jnp.uint32),   # tag stack
            pltpu.VMEM((n_docs, qb), jnp.int32),           # matched buf
            pltpu.VMEM((n_docs, qb), jnp.int32),           # first buf
            # double-buffered raw-byte chunks (+1 lookahead word each)
            pltpu.VMEM((2, chunk // 4 + 1, 1), jnp.int32),
            # compacted (event word, byte pos) rows for one chunk
            pltpu.VMEM((_event_capacity(chunk), 2), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(words, starts, tagmask, pw, pb, selfloop_words, init_words,
      acc_word, acc_bit)
    return matched, first


@functools.partial(jax.jit,
                   static_argnames=("cap", "max_depth", "chunk",
                                    "interpret", "grid_order", "ep_tile"))
def stream_filter_bytes_pallas_sparse(data: jax.Array, starts: jax.Array,
                                      doc_map: jax.Array,
                                      tagmask: jax.Array, pw: jax.Array,
                                      pb: jax.Array,
                                      selfloop_words: jax.Array,
                                      init_words: jax.Array,
                                      acc_word: jax.Array,
                                      acc_bit: jax.Array,
                                      lane_cls: jax.Array, *, cap: int,
                                      max_depth: int, chunk: int = 256,
                                      interpret: bool | None = None,
                                      grid_order: str = "bg",
                                      ep_tile: int = 8
                                      ) -> tuple[jax.Array, jax.Array]:
    """One launch raw bytes → bounded match list.

    The full fused datapath of :func:`stream_filter_bytes_pallas` plus
    the in-kernel sparse epilogue of :func:`stream_filter_pallas_sparse`:
    the ``(S, G, D, QB)`` accept bitmap never exists anywhere —
    per-document accept lanes compact in VMEM into one shared
    ``(cap + win, 3)`` buffer of ``(doc_id, accept_class, first_event)``
    rows.  ``doc_map`` (S, D) int32 names each segment slot's global
    batch row (``SegmentPack.doc_ids``; ``-1`` = unused slot, dropped);
    ``lane_cls`` (G, QB) int32 accept-class names.  Returns
    ``(buf, count)`` with the same validity/overflow contract as the
    event-stream sparse wrapper.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    nseg, length = data.shape
    n_docs = starts.shape[1] - 1
    g, wb = selfloop_words.shape
    qb = acc_word.shape[1]
    n_tags = tagmask.shape[1] - 1
    win = _epilogue_window(qb, ep_tile)
    capp = int(cap) + win
    chunk = max(32, min(_round_up(int(chunk), 32), _round_up(length, 32)))
    npad = _round_up(length, chunk)
    data = jnp.pad(data, ((0, 0), (0, npad - length + 4)))
    words = jax.lax.bitcast_convert_type(
        data.reshape(nseg, npad // 4 + 1, 4), jnp.int32)[..., None]
    grid, doc_axis, by_block, by_doc_block = _grid_maps(grid_order, nseg, g)
    buf, cnt = pl.pallas_call(
        functools.partial(_bytes_kernel_sparse, n_bytes=npad,
                          max_depth=max_depth, chunk=chunk, n_tags=n_tags,
                          n_docs=n_docs, doc_axis=doc_axis, cap=int(cap),
                          win=win),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, n_docs + 1),
                         lambda *ids: by_doc_block(*ids)[:1] + (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_docs),
                         lambda *ids: by_doc_block(*ids)[:1] + (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n_tags + 1, wb),
                         lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb, 32), lambda *ids: by_block(*ids) + (0, 0)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, wb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
            pl.BlockSpec((1, qb), lambda *ids: by_block(*ids) + (0,)),
        ],
        out_specs=[
            pl.BlockSpec((capp, 3), lambda *ids: (0, 0)),
            pl.BlockSpec((1, 1), lambda *ids: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capp, 3), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((max_depth + 2, wb), jnp.uint32),   # tag stack
            pltpu.VMEM((n_docs, qb), jnp.int32),           # matched buf
            pltpu.VMEM((n_docs, qb), jnp.int32),           # first buf
            pltpu.VMEM((2, chunk // 4 + 1, 1), jnp.int32),
            pltpu.VMEM((_event_capacity(chunk), 2), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(words, starts, doc_map, tagmask, pw, pb, selfloop_words, init_words,
      acc_word, acc_bit, lane_cls)
    return buf, cnt
