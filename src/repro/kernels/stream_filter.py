"""Pallas kernel: FPGA-analogue streaming filter with a VMEM stack.

The closest TPU realization of the paper's architecture (Fig 5): state
blocks (one per "hardware region") advance in lock-step over the shared
event stream; each block keeps the document stack in **VMEM** — the
on-chip memory playing the role of the FPGA's block RAM stack (§3.2).

* The event stream lives in SMEM (scalar-fetched once per event — the
  "8-bit streaming XML interface" of Fig 3).
* Each grid program owns one block of ≤BLK states, *closed under parent
  pointers* (the partitioner in :mod:`repro.kernels.blocks` mirrors the
  paper's §3.3 sort-and-cluster flow), so blocks never communicate —
  exactly the property that lets the paper tile thousands of queries.
* The per-event transition is a (1, BLK) × (BLK, BLK) matmul (parent
  gather) plus VPU selects — one MXU issue per event per block.

Outputs per state: ever-active flag and first-active event index; the
caller maps accept states to queries (priority encoder).

Host oracle: :func:`repro.kernels.ref.stream_filter` (pure-jnp scan of
one state block); tests/test_kernels.py asserts exact agreement, and the
end-to-end engine is checked against the recursive oracle engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

NO_MATCH = jnp.iinfo(jnp.int32).max


def _kernel(kind_ref, tag_ref, in_tag_ref, wild_ref, self_ref, init_ref,
            p1h_ref, ever_ref, first_ref, stack_ref, *, n_events: int,
            max_depth: int):
    blk = in_tag_ref.shape[1]
    stack_ref[...] = jnp.zeros_like(stack_ref)
    stack_ref[0, :] = init_ref[0, :]
    in_tag = in_tag_ref[0, :]
    wild = wild_ref[0, :]
    selfloop = self_ref[0, :]
    p1h = p1h_ref[0]

    def body(i, carry):
        depth, ever, first = carry
        k = kind_ref[i]
        t = tag_ref[i]
        is_open = k == ref.OPEN
        is_close = k == ref.CLOSE
        row = stack_ref[pl.dslice(depth, 1), :]                       # (1,BLK)
        tagmatch = (in_tag == t).astype(jnp.float32) + wild
        src = jnp.dot(row, p1h, preferred_element_type=jnp.float32)
        nxt = jnp.minimum(src * tagmatch[None, :] + row * selfloop[None, :],
                          1.0)
        widx = jnp.clip(depth + 1, 0, max_depth + 1)
        old = stack_ref[pl.dslice(widx, 1), :]
        stack_ref[pl.dslice(widx, 1), :] = jnp.where(is_open, nxt, old)
        depth = jnp.clip(
            depth + jnp.where(is_open, 1, jnp.where(is_close, -1, 0)),
            0, max_depth + 1)
        active = jnp.where(is_open, nxt[0], jnp.zeros((blk,), jnp.float32))
        newly = (active > 0) & (ever == 0)
        first = jnp.where(newly, i, first)
        ever = jnp.maximum(ever, active)
        return depth, ever, first

    depth, ever, first = jax.lax.fori_loop(
        0, n_events,
        body,
        (jnp.int32(0), jnp.zeros((blk,), jnp.float32),
         jnp.full((blk,), NO_MATCH, jnp.int32)))
    ever_ref[0, :] = ever
    first_ref[0, :] = first


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "interpret"))
def stream_filter_pallas(kind: jax.Array, tag: jax.Array,
                         in_tag: jax.Array, wild: jax.Array,
                         selfloop: jax.Array, init: jax.Array,
                         parent_1h: jax.Array, *, max_depth: int = 48,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Run all state blocks over one document.

    kind/tag: (N,) int32.  Block tables: in_tag (G, BLK) int32;
    wild/selfloop/init (G, BLK) f32; parent_1h (G, BLK, BLK) f32.
    Returns ever (G, BLK) f32, first (G, BLK) int32.
    ``interpret=None`` auto-detects from the backend.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    g, blk = in_tag.shape
    n = kind.shape[0]
    ever, first = pl.pallas_call(
        functools.partial(_kernel, n_events=n, max_depth=max_depth),
        grid=(g,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # kind
            pl.BlockSpec(memory_space=pltpu.SMEM),          # tag
            pl.BlockSpec((1, blk), lambda i: (i, 0)),       # in_tag
            pl.BlockSpec((1, blk), lambda i: (i, 0)),       # wild
            pl.BlockSpec((1, blk), lambda i: (i, 0)),       # selfloop
            pl.BlockSpec((1, blk), lambda i: (i, 0)),       # init
            pl.BlockSpec((1, blk, blk), lambda i: (i, 0, 0)),  # parent 1h
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, blk), jnp.float32),
            jax.ShapeDtypeStruct((g, blk), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((max_depth + 2, blk), jnp.float32)],
        interpret=interpret,
    )(kind, tag, in_tag, wild, selfloop, init, parent_1h)
    return ever, first
