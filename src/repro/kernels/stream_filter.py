"""Pallas megakernel: batched, bit-packed bytes→verdict streaming filter.

The closest TPU realization of the paper's architecture (Fig 5), and the
default device hot path of ``StreamingEngine``: the whole event→verdict
datapath runs as ONE fused kernel so that — exactly like the FPGA, where
parser and filter share a chip and every symbol advances all query blocks
in a single clock (§1, §3.2–3.4) — no per-event tensor ever leaves the
core.

Layout (see the README "Kernel hot path" diagram):

* **grid = (documents × state-word blocks)** — each program owns one
  document and one block of ≤BLK states *closed under parent pointers*
  (:func:`repro.kernels.blocks.state_layout` mirrors the paper's §3.3
  sort-and-cluster flow), so blocks never communicate — the property
  that lets the paper tile thousands of profiles.  Sharded plans fold
  their part axis into this block axis: more profiles are just more
  blocks, the paper's profiles-across-chips replication.
* **state = packed uint32 words in VMEM, end to end** — the document
  stack is a ``(max_depth+2, BLK/32)`` packed-word buffer in VMEM, the
  on-chip analogue of the FPGA's block-RAM tag stack (§3.2).  There is
  no per-event unpack/repack: the per-event transition is a per-tag
  word-mask row gather plus an in-block parent word/bit gather and three
  bitwise ops — replacing both the scan path's unpack→gather→pack round
  trip and the old float32 ``(BLK, BLK)`` parent matmul.
* **events stream through SMEM chunks** — the fused ``(kind<<16)|tag``
  event words are DMA'd from HBM into a double-buffered SMEM scratch
  (the "8-bit streaming XML interface" of Fig 3); the prefetch of chunk
  *k+1* overlaps the event loop on chunk *k*.

Outputs per (document, block): the block's accept-lane verdict bits and
first-match event indices; the caller maps lanes back to queries (the
paper's priority encoder).

Host oracles: :func:`repro.kernels.ref.stream_filter_words` (pure-jnp
scan of one word-block over the same packed tables — the unit-level
ground truth, tests/test_kernels.py asserts exact agreement) and the
``StreamingEngine`` ``lax.scan`` path (``kernel="scan"``, the end-to-end
oracle — tests/test_megakernel.py asserts the kernel is *bit-identical*
to it on ragged batches, churned plans and depth-overflow documents).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .blocks import _round_up

NO_MATCH = jnp.iinfo(jnp.int32).max

#: fused event word: kind in the high half, tag (uint16 view) in the low
KIND_SHIFT = 16
TAG_MASK = 0xFFFF


def fuse_events(kind: jax.Array, tag: jax.Array) -> jax.Array:
    """(B, N) kind/tag → one int32 event word per event.

    One word per event means one SMEM scalar read per event inside the
    kernel (and one DMA stream instead of two).  PAD events keep working
    unchanged: their kind gates every state/stack/accept update off.
    """
    return ((kind.astype(jnp.int32) << KIND_SHIFT)
            | (tag.astype(jnp.int32) & TAG_MASK))


def _kernel(ev_ref, tagmask_ref, pw_ref, pb_ref, self_ref, init_ref,
            accw_ref, accb_ref, matched_ref, first_ref,
            stack_ref, evbuf_ref, sem_ref, *, n_events: int,
            max_depth: int, chunk: int, n_tags: int):
    b = pl.program_id(0)
    wb = self_ref.shape[1]
    qb = accw_ref.shape[1]
    n_chunks = n_events // chunk
    # fresh document: zero the VMEM stack, root context at depth 0
    stack_ref[...] = jnp.zeros_like(stack_ref)
    stack_ref[0, :] = init_ref[0, :]
    pw = pw_ref[0]                    # (WB, 32) parent word index per lane
    pb = pb_ref[0].astype(jnp.uint32)  # (WB, 32) parent bit index per lane
    selfw = self_ref[0, :]            # (WB,) packed self-loop states
    accw = accw_ref[0, :]             # (QB,) accept-lane word
    accb = accb_ref[0, :].astype(jnp.uint32)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (wb, 32), 1)

    def event_dma(slot, ci):
        # one chunk of this document's fused event words: HBM → SMEM
        return pltpu.make_async_copy(
            ev_ref.at[b, pl.ds(ci * chunk, chunk)],
            evbuf_ref.at[slot], sem_ref.at[slot])

    event_dma(0, 0).start()

    def chunk_body(ci, carry):
        slot = jax.lax.rem(ci, 2)

        # prefetch chunk ci+1 into the other buffer while ci computes
        @pl.when(ci + 1 < n_chunks)
        def _():
            event_dma(1 - slot, ci + 1).start()

        event_dma(slot, ci).wait()

        def ev_body(j, carry):
            depth, matched, first = carry
            ev = evbuf_ref[slot, j]
            k = ev >> KIND_SHIFT
            t = ev & TAG_MASK
            is_open = k == ref.OPEN
            is_close = k == ref.CLOSE
            i = ci * chunk + j
            row = stack_ref[pl.ds(depth, 1), :][0]          # (WB,) packed TOS
            tclip = jnp.where((t >= 0) & (t < n_tags), t, n_tags)
            trow = tagmask_ref[0, pl.ds(tclip, 1), :][0]    # per-tag words
            # in-block parent gather, packed → packed (no unpack/repack
            # of the stack rows; only the 32 source lanes expand)
            bits = (jnp.take(row, pw, axis=0) >> pb) & jnp.uint32(1)
            src = jnp.sum(bits << lane, axis=1, dtype=jnp.uint32)
            nxt = (src & trow) | (selfw & row)
            # push on open (write at depth+1), no-op otherwise — exactly
            # the scan path's clip discipline, so depth overflow degrades
            # identically on both paths
            widx = jnp.clip(depth + 1, 0, max_depth + 1)
            old = stack_ref[pl.ds(widx, 1), :]
            stack_ref[pl.ds(widx, 1), :] = jnp.where(is_open, nxt[None], old)
            depth = jnp.clip(
                depth + jnp.where(is_open, 1, jnp.where(is_close, -1, 0)),
                0, max_depth + 1)
            accbits = (jnp.take(nxt, accw, axis=0) >> accb) & jnp.uint32(1)
            active = is_open & (accbits != 0)
            newly = active & ~matched
            first = jnp.where(newly, i, first)
            matched = matched | active
            return depth, matched, first

        return jax.lax.fori_loop(0, chunk, ev_body, carry)

    depth, matched, first = jax.lax.fori_loop(
        0, n_chunks, chunk_body,
        (jnp.int32(0), jnp.zeros((qb,), bool),
         jnp.full((qb,), NO_MATCH, jnp.int32)))
    matched_ref[0, 0, :] = matched.astype(jnp.int32)
    first_ref[0, 0, :] = first


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "chunk", "interpret"))
def stream_filter_pallas(events: jax.Array, tagmask: jax.Array,
                         pw: jax.Array, pb: jax.Array,
                         selfloop_words: jax.Array, init_words: jax.Array,
                         acc_word: jax.Array, acc_bit: jax.Array, *,
                         max_depth: int, chunk: int = 256,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Run every (document × state-word block) over the event stream.

    events (B, N) int32 fused words (:func:`fuse_events`); block tables
    as emitted by :func:`repro.kernels.blocks.state_layout`: tagmask
    (G, T+1, WB) uint32, pw/pb (G, WB, 32) int32, selfloop/init words
    (G, WB) uint32, acc_word/acc_bit (G, QB) int32.  ``max_depth`` is
    the *plan's* stack bound — callers thread it from plan metadata so
    kernel and scan can never disagree.  Returns matched (B, G, QB)
    int32 0/1 and first (B, G, QB) int32 accept-lane outputs.
    ``interpret=None`` auto-detects from the backend.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    bsz, n = events.shape
    g, wb = selfloop_words.shape
    qb = acc_word.shape[1]
    n_tags = tagmask.shape[1] - 1
    # pad the event axis to whole SMEM chunks with inert PAD events (a
    # short stream shrinks the chunk instead of inflating the pad tail)
    chunk = max(32, min(int(chunk), _round_up(n, 32)))
    npad = _round_up(n, chunk)
    if npad != n:
        events = jnp.pad(events, ((0, 0), (0, npad - n)),
                         constant_values=ref.PAD << KIND_SHIFT)
    matched, first = pl.pallas_call(
        functools.partial(_kernel, n_events=npad, max_depth=max_depth,
                          chunk=chunk, n_tags=n_tags),
        grid=(bsz, g),
        in_specs=[
            # events stay off-core; the kernel DMAs SMEM chunks itself
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, n_tags + 1, wb), lambda b, gg: (gg, 0, 0)),
            pl.BlockSpec((1, wb, 32), lambda b, gg: (gg, 0, 0)),
            pl.BlockSpec((1, wb, 32), lambda b, gg: (gg, 0, 0)),
            pl.BlockSpec((1, wb), lambda b, gg: (gg, 0)),
            pl.BlockSpec((1, wb), lambda b, gg: (gg, 0)),
            pl.BlockSpec((1, qb), lambda b, gg: (gg, 0)),
            pl.BlockSpec((1, qb), lambda b, gg: (gg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qb), lambda b, gg: (b, gg, 0)),
            pl.BlockSpec((1, 1, qb), lambda b, gg: (b, gg, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, g, qb), jnp.int32),
            jax.ShapeDtypeStruct((bsz, g, qb), jnp.int32),
        ],
        scratch_shapes=[
            # the paper's block-RAM tag stack: packed words in VMEM
            pltpu.VMEM((max_depth + 2, wb), jnp.uint32),
            # double-buffered event chunks (the streaming interface)
            pltpu.SMEM((2, chunk), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(events, tagmask, pw, pb, selfloop_words, init_words,
      acc_word, acc_bit)
    return matched, first
