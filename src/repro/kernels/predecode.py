"""Pallas kernel: byte-stream character pre-decode (§3.4).

The paper's pre-decoder turns each incoming byte into 256 one-hot lines so
every matcher consumes 1 bit.  On a TPU the equivalent is decoding *all*
byte positions in parallel into (kind, tag_id) pairs — possible only
because the dictionary replacement (§3.1) makes tags fixed-length, so each
position can be classified without scanning.  Pure VPU arithmetic: no
gathers, no tables.

The wrapper pre-shifts the byte stream by 1..3 positions so each grid
block is self-contained (the halo is materialized, not read across
blocks).  Batched ``(B, L)`` input shifts per document row, so tags
never bleed across document boundaries.

Host oracles: :func:`repro.kernels.ref.predecode` (same per-position
output) and :func:`repro.core.events.decode_bytes` (the compacted event
stream); tests/test_kernels.py and tests/test_ingest.py assert exact
agreement, including on malformed input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANE = 128


def _symbol_value(b: jax.Array) -> jax.Array:
    v = jnp.full_like(b, -1)
    v = jnp.where((b >= 97) & (b <= 122), b - 97, v)
    v = jnp.where((b >= 65) & (b <= 90), b - 65 + 26, v)
    v = jnp.where((b >= 48) & (b <= 57), b - 48 + 52, v)
    v = jnp.where(b == 95, 62, v)
    v = jnp.where(b == 46, 63, v)
    return v


def _kernel(b_ref, b1_ref, b2_ref, b3_ref, kind_ref, tag_ref):
    b = b_ref[...]
    b1, b2, b3 = b1_ref[...], b2_ref[...], b3_ref[...]
    is_lt = b == 60
    is_close = is_lt & (b1 == 47)
    is_open = is_lt & ~is_close
    s0 = jnp.where(is_close, b2, b1)
    s1 = jnp.where(is_close, b3, b2)
    v0, v1 = _symbol_value(s0), _symbol_value(s1)
    ok = (v0 >= 0) & (v1 >= 0)
    kind = jnp.where(is_open & ok, ref.OPEN,
                     jnp.where(is_close & ok, ref.CLOSE, ref.PAD))
    kind_ref[...] = kind.astype(jnp.int32)
    tag_ref[...] = jnp.where(kind != ref.PAD, v0 * 64 + v1, -1).astype(jnp.int32)


def predecode_pallas(bytes_: jax.Array, *, block_rows: int = 8,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """(N,) or (B, N) uint8 → same-shaped (kind int32, tag int32).

    Batched input decodes every document in one ``pallas_call``: the
    1..3-byte halo shifts are materialized *per row* (zero shift-in at
    each document's end), so tags never bleed across document
    boundaries, then all positions go through the grid together.

    Host oracles: :func:`repro.kernels.ref.predecode` (same shapes) and
    :func:`repro.core.events.decode_bytes` (after compaction).
    ``interpret=None`` auto-detects from the backend.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    shape = bytes_.shape
    n = shape[-1]
    b2 = bytes_.astype(jnp.int32).reshape(-1, n)

    def shift(k):
        return jnp.pad(b2[:, k:], ((0, 0), (0, min(k, n))))

    flat = [x.reshape(-1) for x in (b2, shift(1), shift(2), shift(3))]
    total = flat[0].shape[0]
    rows = block_rows
    width = rows * LANE
    n_pad = -total % width
    arrs = [jnp.pad(x, (0, n_pad)).reshape(-1, LANE) for x in flat]
    n_rows = arrs[0].shape[0]
    grid = (n_rows // rows,)
    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    kind, tag = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n_rows, LANE), jnp.int32)] * 2,
        interpret=interpret,
    )(*arrs)
    return kind.reshape(-1)[:total].reshape(shape), \
        tag.reshape(-1)[:total].reshape(shape)
