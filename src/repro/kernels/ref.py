"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# event kinds (match repro.core.events)
OPEN, CLOSE, PAD = 0, 1, 2

# byte constants
_LT, _SLASH = 60, 47


def symbol_value(b: jax.Array) -> jax.Array:
    """Byte → 64-symbol alphabet value (a-zA-Z0-9_.), -1 otherwise.

    Pure arithmetic (no table gather) — the form the TPU kernel uses.
    """
    b = b.astype(jnp.int32)
    v = jnp.full_like(b, -1)
    v = jnp.where((b >= 97) & (b <= 122), b - 97, v)        # a-z → 0..25
    v = jnp.where((b >= 65) & (b <= 90), b - 65 + 26, v)    # A-Z → 26..51
    v = jnp.where((b >= 48) & (b <= 57), b - 48 + 52, v)    # 0-9 → 52..61
    v = jnp.where(b == 95, 62, v)                           # '_'
    v = jnp.where(b == 46, 63, v)                           # '.'
    return v


def predecode(bytes_: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., N) uint8 → per-position (kind, tag_id); kind=PAD off tags.

    The §3.4 character pre-decoder adapted to the TPU: every byte position
    is classified *in parallel* (fixed-length dictionary tags make this
    possible); stream compaction to an event list happens outside.
    Batched input shifts per row, so documents never bleed into each
    other.
    """
    b = bytes_.astype(jnp.int32)
    n = b.shape[-1]

    def shift(k):
        pad = [(0, 0)] * (b.ndim - 1) + [(0, min(k, n))]
        return jnp.pad(b[..., k:], pad)

    b1, b2, b3 = shift(1), shift(2), shift(3)
    is_lt = b == _LT
    is_close = is_lt & (b1 == _SLASH)
    is_open = is_lt & ~is_close
    s0 = jnp.where(is_close, b2, b1)
    s1 = jnp.where(is_close, b3, b2)
    v0, v1 = symbol_value(s0), symbol_value(s1)
    ok = (v0 >= 0) & (v1 >= 0)
    kind = jnp.where(is_open & ok, OPEN,
                     jnp.where(is_close & ok, CLOSE, PAD)).astype(jnp.int32)
    tag = jnp.where(kind != PAD, v0 * 64 + v1, -1).astype(jnp.int32)
    return kind, tag


def nfa_transition(parent_rows: jax.Array, tags: jax.Array, req: jax.Array,
                   wild: jax.Array, parent_1h: jax.Array,
                   selfloop: jax.Array) -> jax.Array:
    """Levelwise NFA transition (one document level, W nodes, S states).

    parent_rows (W, S) f32 0/1 — active sets of each node's parent
    tags        (W,)   int32  — tag id per node (-1 ⇒ padding row)
    req         (T, S) f32    — one-hot tag→state match table
    wild        (S,)   f32    — wildcard-edge states
    parent_1h   (S, S) f32    — P[in_state[s], s] = 1
    selfloop    (S,)   f32
    returns     (W, S) f32 0/1
    """
    n_tags = req.shape[0]
    onehot = jax.nn.one_hot(tags, n_tags, dtype=jnp.float32)
    tagmatch = onehot @ req + wild[None, :]
    src = parent_rows @ parent_1h
    nxt = jnp.minimum(src * tagmatch + parent_rows * selfloop[None, :], 1.0)
    return nxt * (tags >= 0)[:, None].astype(jnp.float32)


def stream_filter_words(events: jax.Array, tagmask: jax.Array,
                        pw: jax.Array, pb: jax.Array,
                        selfloop_words: jax.Array, init_words: jax.Array,
                        acc_word: jax.Array, acc_bit: jax.Array,
                        max_depth: int) -> tuple[jax.Array, jax.Array]:
    """One word-block of the bit-packed streaming megakernel, as a scan.

    The semantic ground truth for
    :func:`repro.kernels.stream_filter.stream_filter_pallas`, one block
    at a time: the same packed-``uint32`` state words, per-tag word
    masks, in-block parent gathers and bounded stack, expressed as a
    ``lax.scan`` over the fused event stream.

    events          (N,) int32 — ``(kind << 16) | (tag & 0xffff)``
    tagmask         (T+1, WB) uint32 — per-tag match words (row T: wild)
    pw / pb         (WB, 32) int32 — parent word / bit per state lane
    selfloop/init   (WB,) uint32 packed words
    acc_word/bit    (QB,) int32 — accept lanes (local word, bit)
    returns         (matched (QB,) bool, first (QB,) int32)
    """
    n = events.shape[0]
    wb = selfloop_words.shape[0]
    n_tags = tagmask.shape[0] - 1
    no_match = jnp.int32(jnp.iinfo(jnp.int32).max)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (wb, 32), 1)

    def step(carry, xs):
        stack, depth, matched, first = carry
        ev, i = xs
        k = ev >> 16
        t = ev & 0xFFFF
        is_open = k == OPEN
        is_close = k == CLOSE
        row = jax.lax.dynamic_index_in_dim(stack, depth, keepdims=False)
        tclip = jnp.where((t >= 0) & (t < n_tags), t, n_tags)
        trow = jax.lax.dynamic_index_in_dim(tagmask, tclip, keepdims=False)
        bits = (jnp.take(row, pw, axis=0)
                >> pb.astype(jnp.uint32)) & jnp.uint32(1)
        src = jnp.sum(bits << lane, axis=1, dtype=jnp.uint32)
        nxt = (src & trow) | (selfloop_words & row)
        widx = jnp.clip(depth + 1, 0, max_depth + 1)
        old = jax.lax.dynamic_index_in_dim(stack, widx, keepdims=False)
        stack = jax.lax.dynamic_update_index_in_dim(
            stack, jnp.where(is_open, nxt, old), widx, 0)
        depth = jnp.clip(depth + jnp.where(is_open, 1,
                                           jnp.where(is_close, -1, 0)),
                         0, max_depth + 1)
        accbits = (jnp.take(nxt, acc_word, axis=0)
                   >> acc_bit.astype(jnp.uint32)) & jnp.uint32(1)
        active = is_open & (accbits != 0)
        newly = active & ~matched
        first = jnp.where(newly, i, first)
        matched = matched | active
        return (stack, depth, matched, first), None

    qb = acc_word.shape[0]
    stack0 = jnp.zeros((max_depth + 2, wb), jnp.uint32).at[0].set(init_words)
    carry0 = (stack0, jnp.int32(0), jnp.zeros(qb, bool),
              jnp.full(qb, no_match, jnp.int32))
    (stack, depth, matched, first), _ = jax.lax.scan(
        step, carry0, (events, jnp.arange(n, dtype=jnp.int32)))
    return matched, first


def sparse_epilogue(matched, first, lane_cls, doc_ids, cap: int, *,
                    grid_order: str = "bg"
                    ) -> tuple[np.ndarray, int]:
    """Block-level oracle for the fused in-kernel sparse epilogue.

    Ground truth for
    :func:`repro.kernels.stream_filter.stream_filter_pallas_sparse` /
    ``stream_filter_bytes_pallas_sparse``: walk the (document-slot ×
    block) grid in the kernel's sequential emission order (doc-major for
    ``"bg"``, block-major for ``"gb"``; within a bytes-kernel cell,
    segment slots in order), compact each cell's accept lanes to
    ``(doc_id, accept_class, first_event)`` rows, and append while the
    running count is below ``cap`` — exactly the kernel's saturating
    write discipline, so ``buf[:min(count, cap)]`` must equal the
    returned rows bit-for-bit even mid-overflow.

    ``matched``/``first`` are the dense kernel outputs — ``(B, G, QB)``
    (event launch, ``doc_ids`` ``(B,)``) or ``(S, G, D, QB)`` (bytes
    launch, ``doc_ids`` ``(S, D)``); ``lane_cls`` ``(G, QB)`` int32
    accept-class names (``-1`` = inert).  Rows with ``doc_id < 0``
    (segment pads) are dropped.  Returns ``(rows, count)`` where
    ``count`` is the TRUE hit total (``count > cap`` ⇒ the device
    buffer overflowed).
    """
    m = np.asarray(matched)
    f = np.asarray(first)
    lc = np.asarray(lane_cls)
    di = np.asarray(doc_ids)
    if m.ndim == 3:                       # event launch: one doc per slot
        m, f, di = m[:, :, None, :], f[:, :, None, :], di[:, None]
    s, g, _, _ = m.shape
    cells = ([(ss, gg) for ss in range(s) for gg in range(g)]
             if grid_order == "bg" else
             [(ss, gg) for gg in range(g) for ss in range(s)])
    rows: list[tuple[int, int, int]] = []
    count = 0
    for ss, gg in cells:
        for dd in range(di.shape[1]):
            doc = int(di[ss, dd])
            hits = (m[ss, gg, dd] != 0) & (lc[gg] >= 0)
            if doc < 0 or not hits.any():
                continue
            for q in np.flatnonzero(hits):
                if count < cap:
                    rows.append((doc, int(lc[gg, q]),
                                 int(f[ss, gg, dd, q])))
                count += 1
    return np.asarray(rows, np.int32).reshape(-1, 3), count
