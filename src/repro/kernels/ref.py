"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# event kinds (match repro.core.events)
OPEN, CLOSE, PAD = 0, 1, 2

# byte constants
_LT, _SLASH = 60, 47


def symbol_value(b: jax.Array) -> jax.Array:
    """Byte → 64-symbol alphabet value (a-zA-Z0-9_.), -1 otherwise.

    Pure arithmetic (no table gather) — the form the TPU kernel uses.
    """
    b = b.astype(jnp.int32)
    v = jnp.full_like(b, -1)
    v = jnp.where((b >= 97) & (b <= 122), b - 97, v)        # a-z → 0..25
    v = jnp.where((b >= 65) & (b <= 90), b - 65 + 26, v)    # A-Z → 26..51
    v = jnp.where((b >= 48) & (b <= 57), b - 48 + 52, v)    # 0-9 → 52..61
    v = jnp.where(b == 95, 62, v)                           # '_'
    v = jnp.where(b == 46, 63, v)                           # '.'
    return v


def predecode(bytes_: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., N) uint8 → per-position (kind, tag_id); kind=PAD off tags.

    The §3.4 character pre-decoder adapted to the TPU: every byte position
    is classified *in parallel* (fixed-length dictionary tags make this
    possible); stream compaction to an event list happens outside.
    Batched input shifts per row, so documents never bleed into each
    other.
    """
    b = bytes_.astype(jnp.int32)
    n = b.shape[-1]

    def shift(k):
        pad = [(0, 0)] * (b.ndim - 1) + [(0, min(k, n))]
        return jnp.pad(b[..., k:], pad)

    b1, b2, b3 = shift(1), shift(2), shift(3)
    is_lt = b == _LT
    is_close = is_lt & (b1 == _SLASH)
    is_open = is_lt & ~is_close
    s0 = jnp.where(is_close, b2, b1)
    s1 = jnp.where(is_close, b3, b2)
    v0, v1 = symbol_value(s0), symbol_value(s1)
    ok = (v0 >= 0) & (v1 >= 0)
    kind = jnp.where(is_open & ok, OPEN,
                     jnp.where(is_close & ok, CLOSE, PAD)).astype(jnp.int32)
    tag = jnp.where(kind != PAD, v0 * 64 + v1, -1).astype(jnp.int32)
    return kind, tag


def nfa_transition(parent_rows: jax.Array, tags: jax.Array, req: jax.Array,
                   wild: jax.Array, parent_1h: jax.Array,
                   selfloop: jax.Array) -> jax.Array:
    """Levelwise NFA transition (one document level, W nodes, S states).

    parent_rows (W, S) f32 0/1 — active sets of each node's parent
    tags        (W,)   int32  — tag id per node (-1 ⇒ padding row)
    req         (T, S) f32    — one-hot tag→state match table
    wild        (S,)   f32    — wildcard-edge states
    parent_1h   (S, S) f32    — P[in_state[s], s] = 1
    selfloop    (S,)   f32
    returns     (W, S) f32 0/1
    """
    n_tags = req.shape[0]
    onehot = jax.nn.one_hot(tags, n_tags, dtype=jnp.float32)
    tagmatch = onehot @ req + wild[None, :]
    src = parent_rows @ parent_1h
    nxt = jnp.minimum(src * tagmatch + parent_rows * selfloop[None, :], 1.0)
    return nxt * (tags >= 0)[:, None].astype(jnp.float32)


def stream_filter(kind: jax.Array, tag: jax.Array, in_tag: jax.Array,
                  wild: jax.Array, selfloop: jax.Array, init: jax.Array,
                  parent_1h: jax.Array, max_depth: int
                  ) -> tuple[jax.Array, jax.Array]:
    """One state-block of the FPGA-analogue streaming filter.

    kind/tag  (N,) int32 — the event stream (shared by all blocks, §3.2)
    in_tag    (BLK,) int32, wild/selfloop/init (BLK,) f32
    parent_1h (BLK, BLK) f32 — block-local parent matrix
    returns   (ever_active (BLK,) f32, first_active (BLK,) int32) — per
    state; accept-state → query mapping is applied by the caller (the
    paper's priority encoder).
    """
    n = kind.shape[0]
    blk = in_tag.shape[0]
    no_match = jnp.int32(jnp.iinfo(jnp.int32).max)

    def step(carry, xs):
        stack, depth, ever, first = carry
        k, t, i = xs
        is_open = k == OPEN
        is_close = k == CLOSE
        row = jax.lax.dynamic_index_in_dim(stack, depth, keepdims=False)
        tagmatch = (in_tag == t).astype(jnp.float32) + wild
        src = row @ parent_1h
        nxt = jnp.minimum(src * tagmatch + row * selfloop, 1.0)
        widx = jnp.clip(depth + 1, 0, max_depth + 1)
        old = jax.lax.dynamic_index_in_dim(stack, widx, keepdims=False)
        stack = jax.lax.dynamic_update_index_in_dim(
            stack, jnp.where(is_open, nxt, old), widx, 0)
        depth = jnp.clip(depth + jnp.where(is_open, 1,
                                           jnp.where(is_close, -1, 0)),
                         0, max_depth + 1)
        active = jnp.where(is_open, nxt, jnp.zeros_like(nxt))
        newly = (active > 0) & (ever == 0)
        first = jnp.where(newly, i, first)
        ever = jnp.maximum(ever, active)
        return (stack, depth, ever, first), None

    stack0 = jnp.zeros((max_depth + 2, blk), jnp.float32).at[0].set(init)
    carry0 = (stack0, jnp.int32(0), jnp.zeros(blk, jnp.float32),
              jnp.full(blk, no_match, jnp.int32))
    (stack, depth, ever, first), _ = jax.lax.scan(
        step, carry0, (kind, tag, jnp.arange(n, dtype=jnp.int32)))
    return ever, first
